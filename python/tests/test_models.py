"""L2 correctness: model geometry, forward shapes, regularizer values."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.energy_lut import cycles_per_mac, energy_lut
from compile.models import BENCHMARKS, get_model
from compile.models.common import apply_model, init_params
from compile.quantlib import PRECISIONS, one_hot_argmax, softmax_temperature

LUT = jnp.asarray(energy_lut())


def hard_assign(model, wbits=8, xbits=8):
    iw = PRECISIONS.index(wbits)
    ix = PRECISIONS.index(xbits)
    assign = {}
    for l in model.qlayers:
        d = jnp.zeros((3,), jnp.float32).at[ix].set(1.0)
        g = jnp.zeros((l.cout, 3), jnp.float32).at[:, iw].set(1.0)
        assign[l.name] = (d, g)
    return assign


def jnp_params(model, mode="cw"):
    p, b, nas = init_params(model, 0, mode)
    return (
        {k: jnp.asarray(v) for k, v in p.items()},
        {k: jnp.asarray(v) for k, v in b.items()},
        {k: jnp.asarray(v) for k, v in nas.items()},
    )


# ---------------------------------------------------------------------------
# Geometry.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bench", list(BENCHMARKS))
def test_geometry_resolves(bench):
    m = get_model(bench)
    assert m.qlayers, bench
    for l in m.qlayers:
        assert l.cin > 0 and l.cout > 0
        assert l.ops > 0
        assert l.weights_per_channel > 0


def test_resnet8_matches_mlperf_tiny():
    m = get_model("ic")
    names = [l.name for l in m.qlayers]
    assert names == ["c1", "b1c1", "b1c2", "b2c1", "b2c2", "b2sc",
                     "b3c1", "b3c2", "b3sc", "fc"]
    # params ~78k (MLPerf ResNet-8)
    total = sum(l.cout * l.weights_per_channel for l in m.qlayers)
    assert 70_000 < total < 90_000, total


def test_vww_mobilenet_channel_plan():
    m = get_model("vww")
    convs = [l for l in m.qlayers if l.kind == "conv"]
    assert convs[0].cout == 8  # 32 * 0.25
    assert convs[-1].cout == 256  # 1024 * 0.25


def test_ad_keeps_128_neurons():
    m = get_model("ad")
    widths = [l.cout for l in m.qlayers]
    assert widths == [128, 128, 8, 128, 128, 256]


# ---------------------------------------------------------------------------
# Forward passes.
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bench", list(BENCHMARKS))
def test_forward_shapes(bench):
    m = get_model(bench)
    params, bn, _ = jnp_params(m)
    x = jnp.ones((2,) + m.input_shape, jnp.float32)
    out, new_bn, reg_s, reg_e = apply_model(
        m, params, bn, hard_assign(m), x,
        train=False, update_stats=None, lut=LUT)
    if m.loss == "ce":
        assert out.shape == (2, m.n_classes)
    else:
        assert out.shape == (2,) + m.input_shape
    assert float(reg_s) > 0 and float(reg_e) > 0


def test_bn_state_updates_only_when_asked():
    m = get_model("ic")
    params, bn, _ = jnp_params(m)
    x = jnp.ones((2,) + m.input_shape, jnp.float32) * 2.0
    _, bn_frozen, _, _ = apply_model(
        m, params, bn, hard_assign(m), x,
        train=True, update_stats=jnp.float32(0.0), lut=LUT)
    _, bn_updated, _, _ = apply_model(
        m, params, bn, hard_assign(m), x,
        train=True, update_stats=jnp.float32(1.0), lut=LUT)
    k = "c1.bn_mean"
    np.testing.assert_allclose(bn_frozen[k], bn[k])
    assert not np.allclose(bn_updated[k], bn[k])


# ---------------------------------------------------------------------------
# Regularizers (Eq. 7 / Eq. 8) against hand computation.
# ---------------------------------------------------------------------------

def test_reg_size_w8_equals_8x_weight_count():
    m = get_model("ad")
    params, bn, _ = jnp_params(m)
    x = jnp.ones((2,) + m.input_shape, jnp.float32)
    _, _, reg_s, _ = apply_model(
        m, params, bn, hard_assign(m, wbits=8), x,
        train=False, update_stats=None, lut=LUT)
    total_weights = sum(l.cout * l.weights_per_channel for l in m.qlayers)
    assert float(reg_s) == pytest.approx(8.0 * total_weights, rel=1e-6)


def test_reg_size_w2_is_quarter_of_w8():
    m = get_model("kws")
    params, bn, _ = jnp_params(m)
    x = jnp.ones((2,) + m.input_shape, jnp.float32)
    _, _, s8, _ = apply_model(m, params, bn, hard_assign(m, 8), x,
                              train=False, update_stats=None, lut=LUT)
    _, _, s2, _ = apply_model(m, params, bn, hard_assign(m, 2), x,
                              train=False, update_stats=None, lut=LUT)
    assert float(s2) == pytest.approx(float(s8) / 4.0, rel=1e-6)


def test_reg_energy_matches_ops_times_lut():
    m = get_model("ad")
    params, bn, _ = jnp_params(m)
    x = jnp.ones((2,) + m.input_shape, jnp.float32)
    lut = energy_lut()
    for (wb, xb) in [(8, 8), (2, 4), (4, 2)]:
        _, _, _, reg_e = apply_model(
            m, params, bn, hard_assign(m, wb, xb), x,
            train=False, update_stats=None, lut=LUT)
        total_ops = sum(l.ops for l in m.qlayers)
        want = total_ops * lut[PRECISIONS.index(xb)][PRECISIONS.index(wb)]
        assert float(reg_e) == pytest.approx(want, rel=1e-5), (wb, xb)


def test_energy_lut_properties():
    lut = energy_lut()
    cyc = cycles_per_mac()
    assert lut.shape == (3, 3) and cyc.shape == (3, 3)
    # monotone in each operand, non-linear overall
    for i in range(3):
        assert np.all(np.diff(lut[i]) >= 0)
        assert np.all(np.diff(lut[:, i]) >= 0)
    assert lut[2][2] / lut[0][0] < 8  # 8x8 not 16x cheaper than 2x2


# ---------------------------------------------------------------------------
# Softmax / argmax consistency (search -> finetune transition).
# ---------------------------------------------------------------------------

def test_softmax_temperature_anneals_to_argmax():
    theta = jnp.array([[0.3, 1.2, -0.5]], jnp.float32)
    hot = one_hot_argmax(theta, 3)
    cold = softmax_temperature(theta, jnp.float32(0.01))
    np.testing.assert_allclose(cold, hot, atol=1e-4)
    warm = softmax_temperature(theta, jnp.float32(5.0))
    assert float(jnp.max(warm)) < 0.5  # still soft at tau=5


def test_softmax_rows_sum_to_one():
    rng = np.random.default_rng(0)
    theta = jnp.asarray(rng.normal(0, 3, (16, 3)).astype(np.float32))
    for tau in [5.0, 1.0, 0.1]:
        s = softmax_temperature(theta, jnp.float32(tau))
        np.testing.assert_allclose(jnp.sum(s, axis=-1), np.ones(16), rtol=1e-5)
