"""L2 training-graph semantics: the six step functions behave per Alg. 1."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.models import get_model
from compile.models.common import init_params
from compile.train_graphs import GraphSet


@pytest.fixture(scope="module")
def ad_setup():
    m = get_model("ad")
    gs = GraphSet(m, "cw", 0)
    p0, b0, n0 = init_params(m, 0, "cw")
    plist = [jnp.asarray(v) for v in p0.values()]
    blist = [jnp.asarray(v) for v in b0.values()]
    nlist = [jnp.asarray(v) for v in n0.values()]
    rng = np.random.default_rng(0)
    x = jnp.asarray(np.abs(rng.normal(1.0, 0.3, (32, 256))).astype(np.float32))
    hard = []
    for l in m.qlayers:
        d = jnp.zeros(3, jnp.float32).at[2].set(1.0)
        g = jnp.zeros((l.cout, 3), jnp.float32).at[:, 2].set(1.0)
        hard += [d, g]
    return m, gs, plist, blist, nlist, x, hard


def zeros_like(ts):
    return [jnp.zeros_like(t) for t in ts]


def test_train_w_hard_reduces_loss(ad_setup):
    m, gs, plist, blist, nlist, x, hard = ad_setup
    f = jax.jit(gs.train_w_hard)
    np_, nb = len(plist), len(blist)
    state = (list(plist), list(blist), zeros_like(plist), zeros_like(plist))
    losses = []
    for t in range(25):
        out = f(state[0], state[1], state[2], state[3], jnp.float32(t),
                hard, x, x, jnp.float32(2e-3))
        state = (
            list(out[:np_]),
            list(out[np_:np_ + nb]),
            list(out[np_ + nb:2 * np_ + nb]),
            list(out[2 * np_ + nb:3 * np_ + nb]),
        )
        losses.append(float(out[-2]))
    assert losses[-1] < losses[0] * 0.8, losses[::6]


def test_search_theta_only_updates_nas(ad_setup):
    m, gs, plist, blist, nlist, x, hard = ad_setup
    f = jax.jit(gs.search_theta)
    out = f(plist, blist, nlist, zeros_like(nlist), zeros_like(nlist),
            jnp.float32(0), x, x, jnp.float32(5.0),
            jnp.float32(1e-5), jnp.float32(0.0), jnp.float32(1e-2),
            jnp.float32(0.0))
    nn = len(nlist)
    new_nas = out[:nn]
    changed = sum(
        int(not np.allclose(a, b)) for a, b in zip(new_nas, nlist))
    assert changed > 0, "no NAS parameter moved"
    # regularizer outputs are positive scalars
    assert float(out[-2]) > 0 and float(out[-1]) > 0


def test_act_freeze_masks_delta_updates(ad_setup):
    m, gs, plist, blist, nlist, x, hard = ad_setup
    f = jax.jit(gs.search_theta)
    out = f(plist, blist, nlist, zeros_like(nlist), zeros_like(nlist),
            jnp.float32(0), x, x, jnp.float32(5.0),
            jnp.float32(1e-4), jnp.float32(0.0), jnp.float32(1e-2),
            jnp.float32(1.0))  # act_freeze = 1
    nn = len(nlist)
    for name, old, new in zip(gs.nnames, nlist, out[:nn]):
        if name.endswith(".delta"):
            np.testing.assert_allclose(old, new, err_msg=name)
        else:
            assert not np.allclose(old, new), f"{name} should move"


def test_size_lambda_pushes_gamma_to_2bit(ad_setup):
    """With a huge size lambda the gammas must drift towards 2 bit."""
    m, gs, plist, blist, nlist, x, hard = ad_setup
    f = jax.jit(gs.search_theta)
    nas = list(nlist)
    mn, vn = zeros_like(nlist), zeros_like(nlist)
    for t in range(20):
        out = f(plist, blist, nas, mn, vn, jnp.float32(t), x, x,
                jnp.float32(5.0), jnp.float32(1e-2), jnp.float32(0.0),
                jnp.float32(5e-2), jnp.float32(1.0))
        nn = len(nlist)
        nas = list(out[:nn])
        mn = list(out[nn:2 * nn])
        vn = list(out[2 * nn:3 * nn])
    for name, t_ in zip(gs.nnames, nas):
        if name.endswith(".gamma"):
            g = np.asarray(t_)
            # column 0 (2-bit) must dominate on average
            assert g[:, 0].mean() > g[:, 2].mean(), name


def test_search_w_updates_weights_not_nas(ad_setup):
    m, gs, plist, blist, nlist, x, hard = ad_setup
    f = jax.jit(gs.search_w)
    out = f(plist, blist, nlist, zeros_like(plist), zeros_like(plist),
            jnp.float32(0), x, x, jnp.float32(5.0), jnp.float32(1e-3))
    np_ = len(plist)
    new_p = out[:np_]
    moved = sum(int(not np.allclose(a, b)) for a, b in zip(new_p, plist))
    assert moved > len(plist) // 2


def test_eval_consistent_with_infer(ad_setup):
    m, gs, plist, blist, nlist, x, hard = ad_setup
    loss, metric, per_sample, reg_s, reg_e = jax.jit(gs.eval_hard)(
        plist, blist, hard, x, x)
    out = jax.jit(gs.infer_hard)(plist, blist, hard, x)
    # per-sample mse from infer must equal eval's per_sample
    want = np.mean((np.asarray(out) - np.asarray(x)) ** 2, axis=1)
    np.testing.assert_allclose(per_sample, want, rtol=1e-5)
    assert float(loss) == pytest.approx(float(np.mean(want)), rel=1e-5)


def test_lw_mode_gamma_is_per_layer():
    m = get_model("ad")
    gs = GraphSet(m, "lw", 0)
    for name, shape in gs.nshapes.items():
        if name.endswith(".gamma"):
            assert shape[0] == 1, name
