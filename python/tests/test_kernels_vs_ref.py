"""L1 correctness: every Pallas kernel vs the pure-jnp oracle.

Hypothesis sweeps shapes and value ranges; results must match to 1 ULP
(same math; interpret-mode may fuse mul/div differently).  This is the
CORE correctness signal for the kernels inside every AOT'd graph.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fake_quant import (
    pact_fake_quant_pallas,
    weight_fake_quant_pallas,
)
from compile.kernels.intgemm import int_gemm_pallas
from compile.kernels.mixed_weight import mixed_act_pallas, mixed_weight_pallas
from compile.quantlib import PRECISIONS, softmax_temperature

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")


dims = st.integers(min_value=1, max_value=40)
bits = st.sampled_from(PRECISIONS)


def rand(rng, *shape):
    return jnp.asarray(rng.normal(0.4, 1.0, shape).astype(np.float32))


# ---------------------------------------------------------------------------
# PACT fake-quant.
# ---------------------------------------------------------------------------

@given(r=dims, c=dims, n=bits, alpha=st.floats(0.1, 8.0), seed=st.integers(0, 999))
def test_pact_matches_ref_2d(r, c, n, alpha, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, r, c)
    a = jnp.float32(alpha)
    got = pact_fake_quant_pallas(x, a, n)
    want = ref.pact_fake_quant_ref(x, a, n)
    # interpret-mode fuses mul/div differently: allow 1-ULP drift
    np.testing.assert_allclose(got, want, rtol=2e-7, atol=1e-7)


@given(shape=st.lists(dims, min_size=1, max_size=4), n=bits, seed=st.integers(0, 99))
def test_pact_any_rank(shape, n, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, *shape)
    a = jnp.float32(2.5)
    got = pact_fake_quant_pallas(x, a, n)
    want = ref.pact_fake_quant_ref(x, a, n)
    assert got.shape == tuple(shape)
    np.testing.assert_allclose(got, want, rtol=2e-7, atol=1e-7)


def test_pact_quant_levels():
    # outputs must take at most 2^n distinct values
    rng = np.random.default_rng(0)
    x = rand(rng, 64, 64)
    for n in PRECISIONS:
        y = np.unique(np.asarray(pact_fake_quant_pallas(x, jnp.float32(4.0), n)))
        assert len(y) <= 2 ** n


def test_pact_gradients_ste_and_alpha():
    x = jnp.array([[-1.0, 0.5, 3.0, 10.0]], jnp.float32)
    a = jnp.float32(4.0)

    def f(x, a):
        return jnp.sum(pact_fake_quant_pallas(x, a, 4) * 2.0)

    gx, ga = jax.grad(f, argnums=(0, 1))(x, a)
    # STE: in-range passes, clipped blocks
    np.testing.assert_allclose(gx, [[0.0, 2.0, 2.0, 0.0]])
    # PACT: saturated element contributes to alpha
    assert float(ga) == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# Per-channel weight fake-quant.
# ---------------------------------------------------------------------------

@given(cout=dims, k=dims, n=bits, seed=st.integers(0, 999))
def test_weight_fq_matches_ref(cout, k, n, seed):
    rng = np.random.default_rng(seed)
    w = rand(rng, cout, k) * 0.3
    got = weight_fake_quant_pallas(w, n)
    want = ref.weight_fake_quant_ref(w, n)
    np.testing.assert_allclose(got, want, rtol=2e-7, atol=1e-7)


def test_weight_fq_is_per_channel():
    # scaling one row must not change another row's quantization
    rng = np.random.default_rng(1)
    w = rand(rng, 4, 16) * 0.2
    base = np.asarray(weight_fake_quant_pallas(w, 4))
    w2 = w.at[0].multiply(100.0)
    scaled = np.asarray(weight_fake_quant_pallas(w2, 4))
    np.testing.assert_allclose(base[1:], scaled[1:])


def test_weight_fq_ste_gradient():
    w = jnp.ones((3, 5), jnp.float32) * 0.3
    g = jax.grad(lambda w: jnp.sum(weight_fake_quant_pallas(w, 2) * 3.0))(w)
    np.testing.assert_allclose(g, np.full((3, 5), 3.0))


# ---------------------------------------------------------------------------
# Eq. (5) fused blend.
# ---------------------------------------------------------------------------

@given(cout=dims, k=dims, seed=st.integers(0, 999), tau=st.floats(0.05, 5.0))
def test_mixed_weight_matches_ref(cout, k, seed, tau):
    rng = np.random.default_rng(seed)
    w = rand(rng, cout, k) * 0.3
    gamma = rand(rng, cout, 3)
    gh = softmax_temperature(gamma, jnp.float32(tau))
    got = mixed_weight_pallas(w, gh)
    want = ref.mixed_weight_ref(w, gh)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_mixed_weight_one_hot_equals_single_precision():
    rng = np.random.default_rng(3)
    w = rand(rng, 8, 20) * 0.2
    for j, p in enumerate(PRECISIONS):
        gh = jnp.zeros((8, 3), jnp.float32).at[:, j].set(1.0)
        got = mixed_weight_pallas(w, gh)
        want = ref.weight_fake_quant_ref(w, p)
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-7)


def test_mixed_weight_gradients():
    rng = np.random.default_rng(4)
    w = rand(rng, 6, 10) * 0.3
    gh = jnp.full((6, 3), 1.0 / 3.0, jnp.float32)

    def f(w, gh):
        return jnp.sum(mixed_weight_pallas(w, gh) ** 2)

    gw, gg = jax.grad(f, argnums=(0, 1))(w, gh)
    assert gw.shape == w.shape
    assert gg.shape == gh.shape
    # gamma gradient columns = <2*what, fq(w,p)>: verify one numerically
    y = np.asarray(mixed_weight_pallas(w, gh))
    want_col0 = np.sum(2 * y * np.asarray(ref.weight_fake_quant_ref(w, 2)), axis=1)
    np.testing.assert_allclose(gg[:, 0], want_col0, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Eq. (4) fused blend.
# ---------------------------------------------------------------------------

@given(r=dims, c=dims, seed=st.integers(0, 999))
def test_mixed_act_matches_ref(r, c, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, r, c)
    a = jnp.float32(3.0)
    dh = softmax_temperature(rand(rng, 3).reshape(3), jnp.float32(1.0))
    got = mixed_act_pallas(x, a, dh)
    want = ref.mixed_act_ref(x, a, dh)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_mixed_act_gradients_flow_to_all():
    rng = np.random.default_rng(5)
    x = rand(rng, 4, 8)
    a = jnp.float32(2.0)
    dh = jnp.array([0.2, 0.3, 0.5], jnp.float32)

    def f(x, a, d):
        return jnp.sum(mixed_act_pallas(x, a, d))

    gx, ga, gd = jax.grad(f, argnums=(0, 1, 2))(x, a, dh)
    assert gx.shape == x.shape
    assert gd.shape == (3,)
    assert np.all(np.asarray(gd) > 0)  # each precision contributes


# ---------------------------------------------------------------------------
# Integer GEMM.
# ---------------------------------------------------------------------------

@given(m=dims, k=dims, n=dims, seed=st.integers(0, 999))
def test_int_gemm_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = jnp.asarray(rng.integers(0, 256, (m, k)).astype(np.float32))
    b = jnp.asarray(rng.integers(-128, 128, (k, n)).astype(np.float32))
    got = int_gemm_pallas(a, b)
    want = ref.int_gemm_ref(a, b)
    np.testing.assert_allclose(got, want, rtol=0, atol=0)


def test_int_gemm_large_tiled():
    rng = np.random.default_rng(7)
    a = jnp.asarray(rng.integers(0, 16, (300, 64)).astype(np.float32))
    b = jnp.asarray(rng.integers(-8, 8, (64, 200)).astype(np.float32))
    np.testing.assert_allclose(int_gemm_pallas(a, b), ref.int_gemm_ref(a, b))
