"""AOT lowering: JAX graphs -> HLO text artifacts + manifest.json.

Usage (from ``python/``):
    python -m compile.aot --out ../artifacts [--bench ic,kws,vww,ad]

For every benchmark this emits::

    artifacts/<bench>/
        train_w_hard.hlo.txt      # warmup / finetune / fixed baselines
        search_theta_cw.hlo.txt   # Alg.1 line 5, channel-wise (ours)
        search_theta_lw.hlo.txt   # Alg.1 line 5, layer-wise (EdMIPS)
        search_w_cw.hlo.txt       # Alg.1 line 7
        search_w_lw.hlo.txt
        eval.hlo.txt
        infer.hlo.txt
        manifest.json             # tensor order/shapes, model geometry, LUT

HLO **text** is the interchange format (not ``.serialize()``): jax >= 0.5
emits HloModuleProto with 64-bit instruction ids which the xla crate's
xla_extension 0.5.1 rejects; the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Python runs exactly once per artifact set; the Rust binary is self-contained
afterwards.  ``make artifacts`` skips this when inputs are unchanged.
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from .energy_lut import cycles_per_mac, energy_lut
from .models import get_model
from .models.common import init_params
from .quantlib import PRECISIONS
from .train_graphs import GraphSet

BATCH = 32
SEED = 0


def to_hlo_text(lowered) -> str:
    """stablehlo -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _scalar():
    return _spec(())


class Lowerer:
    """Builds the input specs for one benchmark and lowers all graphs."""

    def __init__(self, bench: str):
        self.bench = bench
        self.model = get_model(bench)
        self.gs_cw = GraphSet(self.model, "cw", SEED)
        self.gs_lw = GraphSet(self.model, "lw", SEED)

    # ---- spec helpers ------------------------------------------------------

    def param_specs(self, gs: GraphSet):
        return [_spec(gs.pshapes[k]) for k in gs.pnames]

    def bn_specs(self, gs: GraphSet):
        return [_spec(gs.bshapes[k]) for k in gs.bnames]

    def nas_specs(self, gs: GraphSet):
        return [_spec(gs.nshapes[k]) for k in gs.nnames]

    def hard_specs(self, gs: GraphSet):
        return [_spec(shape) for _, shape in gs.hard_shapes()]

    def batch_specs(self):
        m = self.model
        x = _spec((BATCH,) + m.input_shape)
        if m.loss == "ce":
            y = _spec((BATCH,), jnp.int32)
        else:
            y = _spec((BATCH,) + m.input_shape)
        return x, y

    # ---- graph lowering ----------------------------------------------------

    def lower_all(self):
        gs = self.gs_cw
        p, b = self.param_specs(gs), self.bn_specs(gs)
        hard = self.hard_specs(gs)
        x, y = self.batch_specs()
        s = _scalar()

        graphs = {}

        graphs["train_w_hard"] = jax.jit(gs.train_w_hard, keep_unused=True).lower(
            p, b, p, p, s, hard, x, y, s)

        for mode, g in (("cw", self.gs_cw), ("lw", self.gs_lw)):
            n = self.nas_specs(g)
            graphs[f"search_theta_{mode}"] = jax.jit(g.search_theta, keep_unused=True).lower(
                p, b, n, n, n, s, x, y, s, s, s, s, s)
            graphs[f"search_w_{mode}"] = jax.jit(g.search_w, keep_unused=True).lower(
                p, b, n, p, p, s, x, y, s, s)

        graphs["eval"] = jax.jit(gs.eval_hard, keep_unused=True).lower(p, b, hard, x, y)
        graphs["infer"] = jax.jit(gs.infer_hard, keep_unused=True).lower(p, b, hard, x)
        return graphs

    # ---- manifest ----------------------------------------------------------

    def manifest(self) -> dict:
        gs = self.gs_cw
        m = self.model
        p0, b0, n0 = init_params(m, SEED, "cw")
        _, _, n0_lw = init_params(m, SEED, "lw")
        return {
            "benchmark": self.bench,
            "batch": BATCH,
            "seed": SEED,
            "precisions": list(PRECISIONS),
            "loss": m.loss,
            "n_classes": m.n_classes,
            "input_shape": list(m.input_shape),
            "layers": m.manifest_layers(),
            "params": [{"name": k, "shape": list(np.shape(v))}
                       for k, v in p0.items()],
            "bn_state": [{"name": k, "shape": list(np.shape(v))}
                         for k, v in b0.items()],
            "nas_cw": [{"name": k, "shape": list(np.shape(v))}
                       for k, v in n0.items()],
            "nas_lw": [{"name": k, "shape": list(np.shape(v))}
                       for k, v in n0_lw.items()],
            "hard_assign": [{"name": n, "shape": list(s)}
                            for n, s in gs.hard_shapes()],
            "energy_lut_pj_per_mac": [[float(v) for v in row]
                                      for row in energy_lut()],
            "cycles_per_mac": [[float(v) for v in row]
                               for row in cycles_per_mac()],
            "graphs": {
                "train_w_hard": {
                    "inputs": "params,bn,adam_m,adam_v,t,hard,x,y,lr",
                    "outputs": "params,bn,adam_m,adam_v,loss,metric"},
                "search_theta": {
                    "inputs": "params,bn,nas,adam_m,adam_v,t,x,y,tau,"
                              "lam_size,lam_energy,lr,act_freeze",
                    "outputs": "nas,adam_m,adam_v,loss,reg_size,reg_energy"},
                "search_w": {
                    "inputs": "params,bn,nas,adam_m,adam_v,t,x,y,tau,lr",
                    "outputs": "params,bn,adam_m,adam_v,loss,metric"},
                "eval": {
                    "inputs": "params,bn,hard,x,y",
                    "outputs": "loss,metric,per_sample,reg_size,reg_energy"},
                "infer": {"inputs": "params,bn,hard,x", "outputs": "out"},
            },
        }


def emit_benchmark(bench: str, outdir: str) -> None:
    os.makedirs(os.path.join(outdir, bench), exist_ok=True)
    low = Lowerer(bench)
    for name, lowered in low.lower_all().items():
        path = os.path.join(outdir, bench, f"{name}.hlo.txt")
        text = to_hlo_text(lowered)
        with open(path, "w") as f:
            f.write(text)
        print(f"  {bench}/{name}: {len(text) / 1e6:.1f} MB")
    with open(os.path.join(outdir, bench, "manifest.json"), "w") as f:
        json.dump(low.manifest(), f, indent=1)
    print(f"  {bench}/manifest.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--bench", default="ic,kws,vww,ad")
    args = ap.parse_args()
    for bench in args.bench.split(","):
        print(f"[aot] lowering {bench} ...")
        emit_benchmark(bench.strip(), args.out)
    print("[aot] done")


if __name__ == "__main__":
    main()
