"""The MPIC energy/OP LUT ``C(p_x, p_w)`` (Eq. (8)) — single source of truth.

Derived from the MPIC core's mixed-precision SIMD dot-product unit (Ottavi
et al., ISVLSI 2020) as documented in DESIGN.md §7: lane throughput is set
by the *wider* operand (8-bit: 4 MACs/cycle, 4-bit: 8, 2-bit: 16); energy/OP
is core power x cycle time / throughput with a datapath factor kappa < 1
for narrower operands (narrower multipliers gate less logic).

Values are pJ/MAC at 250 MHz with P_core = 1.75 mW.  The table is emitted
into every ``manifest.json`` by ``aot.py``; ``rust/src/energy/lut.rs``
mirrors it and an integration test cross-checks the two, so the NAS
regularizer (Eq. 8, baked into the HLO graphs) and the Rust-side reporting
can never drift apart.

The paper's key property is preserved: energy is **not** linear in
bit-width (2x2 is 4.4x — not 16x — cheaper than 8x8).
"""

from __future__ import annotations

import numpy as np

# Index order: [p_x][p_w] over PRECISIONS = (2, 4, 8).
# thr(p_x, p_w) = MACs/cycle = 16 / max(p_x, p_w) * (lane pairing factor 1)
_THR = np.array([
    # p_w:  2     4     8
    [16.0, 8.0, 4.0],   # p_x = 2
    [8.0, 8.0, 4.0],    # p_x = 4
    [4.0, 4.0, 4.0],    # p_x = 8
])

# Datapath gating factor: narrower operand pairs burn slightly less
# switching energy per cycle.
_KAPPA = np.array([
    [0.85, 0.88, 0.92],
    [0.88, 0.90, 0.95],
    [0.92, 0.95, 1.00],
])

_P_CORE_MW = 1.75
_F_MHZ = 250.0
_PJ_PER_CYCLE = _P_CORE_MW * 1e-3 / (_F_MHZ * 1e6) * 1e12  # = 7.0 pJ/cycle


def energy_lut() -> np.ndarray:
    """(3, 3) float32 pJ/MAC table, rows = p_x in (2,4,8), cols = p_w."""
    return (_PJ_PER_CYCLE / _THR * _KAPPA).astype(np.float32)


def cycles_per_mac() -> np.ndarray:
    """(3, 3) float32 cycles/MAC table (for the latency model)."""
    return (1.0 / _THR).astype(np.float32)
