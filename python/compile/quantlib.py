"""Affine / PACT quantization primitives (Eq. (1) of the paper).

This module is the *mathematical* definition shared by:
  * the Pallas kernels in ``kernels/`` (which implement the same functions as
    tiled TPU-shaped kernels and are checked against ``kernels/ref.py``);
  * the pure-jnp reference oracle (``kernels/ref.py``);
  * the straight-through-estimator (STE) custom VJPs used by the training
    graphs in ``train_graphs.py``.

Quantization schemes
--------------------
Activations use **PACT** [Choi et al. 2018]: a learned clipping value
``alpha`` per layer, unsigned range ``[0, alpha]`` mapped onto
``[0, 2^n - 1]`` integers:

    eps   = alpha / (2^n - 1)
    x_q   = round(clamp(x, 0, alpha) / eps) * eps

Weights use symmetric per-channel affine quantization onto signed
``[-(2^(n-1) - 1), 2^(n-1) - 1]`` with a per-output-channel scale equal to
the channel's max absolute value:

    s_i   = max|W_i| / (2^(n-1) - 1)
    w_q,i = round(clamp(W_i, -max|W_i|, max|W_i|) / s_i) * s_i

Both are *fake* quantization: the returned tensors are float but take only
``2^n`` distinct values, so the forward pass sees exactly the deployed
arithmetic (the MPIC integer pipeline is ``scale * int_conv``, which is the
same numbers modulo float rounding).

Gradients
---------
``round`` is a step function; the STE passes gradients through inside the
clipping range and blocks them outside.  For PACT, ``d x_q / d alpha = 1``
for saturated inputs (the original PACT rule), which is what lets the
clipping range be learned jointly with the weights.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# Bit-width sets searched by the NAS (the paper's P_W = P_X = {2, 4, 8}).
PRECISIONS = (2, 4, 8)
PMAX = 8


def qlevels_act(n_bits: int) -> int:
    """Number of positive quantization steps for an unsigned activation."""
    return (1 << n_bits) - 1


def qlevels_weight(n_bits: int) -> int:
    """Max magnitude of the signed symmetric integer grid for weights."""
    return (1 << (n_bits - 1)) - 1


# ---------------------------------------------------------------------------
# PACT activation fake-quantization (per-tensor alpha), with custom VJP.
# ---------------------------------------------------------------------------

def _make_pact():
    @functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
    def _f(x, alpha, n_bits):
        levels = (1 << n_bits) - 1
        a = jnp.maximum(alpha, 1e-6)
        eps = a / levels
        xc = jnp.clip(x, 0.0, a)
        return jnp.round(xc / eps) * eps

    def fwd(x, alpha, n_bits):
        return _f(x, alpha, n_bits), (x, alpha)

    def bwd(n_bits, res, g):
        x, alpha = res
        a = jnp.maximum(alpha, 1e-6)
        in_range = jnp.logical_and(x >= 0.0, x <= a)
        gx = jnp.where(in_range, g, 0.0)
        # PACT: saturated inputs contribute d y / d alpha = 1.
        galpha = jnp.sum(jnp.where(x > a, g, 0.0))
        return gx, galpha.reshape(jnp.shape(alpha)).astype(g.dtype)

    _f.defvjp(fwd, bwd)
    return _f


pact_fake_quant = _make_pact()
"""``pact_fake_quant(x, alpha, n_bits)`` — PACT fake quantization.

``alpha`` is a scalar array (per-layer learned clipping value); ``n_bits``
must be a static Python int.  Custom VJP: STE on ``x`` inside ``[0, alpha]``,
PACT rule on ``alpha`` (gradient collected from saturated inputs).
"""


# ---------------------------------------------------------------------------
# Per-channel symmetric weight fake-quantization, with STE VJP.
# ---------------------------------------------------------------------------

def weight_scale(w2d: jax.Array, n_bits: int) -> jax.Array:
    """Per-row (= per output channel) quantization step, shape (Cout, 1)."""
    levels = qlevels_weight(n_bits)
    amax = jnp.max(jnp.abs(w2d), axis=1, keepdims=True)
    return jnp.maximum(amax, 1e-8) / levels


def _make_weight_fq():
    @functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
    def _f(w2d, n_bits):
        levels = (1 << (n_bits - 1)) - 1
        s = weight_scale(w2d, n_bits)
        q = jnp.clip(jnp.round(w2d / s), -levels, levels)
        return q * s

    def fwd(w2d, n_bits):
        return _f(w2d, n_bits), ()

    def bwd(n_bits, res, g):
        # Pure STE: the scale is data-dependent (max|w|) but is treated as a
        # constant for the backward pass, matching standard QAT practice.
        return (g,)

    _f.defvjp(fwd, bwd)
    return _f


weight_fake_quant = _make_weight_fq()
"""``weight_fake_quant(w2d, n_bits)`` — per-channel symmetric fake quant.

``w2d`` must be reshaped to ``(C_out, K)`` where ``K = C_in * Kx * Ky``; the
scale is per row.  STE backward.
"""


def weight_fake_quant_nd(w: jax.Array, n_bits: int) -> jax.Array:
    """Fake-quantize a conv weight of shape (Cout, ...) channel-wise."""
    flat = w.reshape(w.shape[0], -1)
    return weight_fake_quant(flat, n_bits).reshape(w.shape)


# ---------------------------------------------------------------------------
# Softmax with temperature (Eq. (3)).
# ---------------------------------------------------------------------------

def softmax_temperature(theta: jax.Array, tau: jax.Array) -> jax.Array:
    """Row-wise softmax with temperature ``tau`` along the last axis.

    Matches Eq. (3): ``SM(x; tau)_i = exp(x_i / tau) / sum_j exp(x_j / tau)``.
    As ``tau`` is annealed towards 0 the output approaches a one-hot argmax.
    """
    t = jnp.maximum(tau, 1e-4)
    z = theta / t
    z = z - jnp.max(z, axis=-1, keepdims=True)
    e = jnp.exp(z)
    return e / jnp.sum(e, axis=-1, keepdims=True)


def one_hot_argmax(theta: jax.Array, n: int) -> jax.Array:
    """Hard argmax selection used after the search phase (row-wise)."""
    idx = jnp.argmax(theta, axis=-1)
    return jax.nn.one_hot(idx, n, dtype=theta.dtype)
