"""Generic layer-graph model definition shared by all four benchmarks.

A model is a sequential list of :class:`LayerDef` with optional residual
taps (``save_as`` / ``add_from`` / ``input_from``), enough to express
ResNet-8, DS-CNN, MobileNetV1 and the AD autoencoder.  The same structure
is exported to ``manifest.json`` and re-parsed by ``rust/src/models/`` so
the Rust coordinator, the energy model and the MPIC simulator all see the
exact geometry that was trained.

Quantized layers (conv / dwconv / fc) are numbered in appearance order;
layer ``q`` owns NAS parameters ``delta_q`` (|P_X|) and ``gamma_q``
(C_out x |P_W| channel-wise, 1 x |P_W| layer-wise) plus a PACT ``alpha_q``.

Parameter naming convention (manifest + Rust side rely on it):
    <layer>.w, <layer>.b, <layer>.bn_scale, <layer>.bn_bias, <layer>.alpha
    state:  <layer>.bn_mean, <layer>.bn_var
    nas:    <layer>.delta, <layer>.gamma
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .. import nas_layers as nl
from ..quantlib import PRECISIONS

QUANT_KINDS = ("conv", "dwconv", "fc")


@dataclass
class LayerDef:
    """One node of the sequential layer graph."""
    name: str
    kind: str                 # conv | dwconv | fc | avgpool | flatten | add | tap
    cout: int = 0
    kx: int = 1
    ky: int = 1
    stride: int = 1
    relu: bool = True
    bn: bool = True
    bias: bool = False
    save_as: str | None = None    # store this layer's output under a tag
    add_from: str | None = None   # residual add with a saved tag (before relu)
    input_from: str | None = None  # read input from a saved tag, not the chain
    # filled in by build_model:
    cin: int = 0
    in_h: int = 0
    in_w: int = 0
    out_h: int = 0
    out_w: int = 0
    qidx: int = -1            # index among quantized layers, -1 if structural

    @property
    def is_quant(self) -> bool:
        return self.kind in QUANT_KINDS

    @property
    def groups(self) -> int:
        return self.cin if self.kind == "dwconv" else 1

    @property
    def weight_shape(self):
        if self.kind == "fc":
            return (self.cout, self.cin)
        cin_g = 1 if self.kind == "dwconv" else self.cin
        return (self.cout, self.kx, self.ky, cin_g)

    @property
    def weights_per_channel(self) -> int:
        """K = C_in * Kx * Ky of Eq. (7) (per output channel)."""
        if self.kind == "fc":
            return self.cin
        return (1 if self.kind == "dwconv" else self.cin) * self.kx * self.ky

    @property
    def ops(self) -> int:
        """Total MACs to produce this layer's output (Omega of Eq. (8))."""
        if self.kind == "fc":
            return self.cout * self.cin
        return self.out_h * self.out_w * self.cout * self.weights_per_channel


@dataclass
class ModelDef:
    """A built model: geometry-resolved layers + loss/score type."""
    name: str
    layers: list[LayerDef]
    input_shape: tuple          # (H, W, C) or (D,) for the autoencoder
    n_classes: int              # 0 for the AD reconstruction task
    loss: str                   # 'ce' | 'mse'
    qlayers: list[LayerDef] = field(default_factory=list)

    def manifest_layers(self):
        out = []
        for l in self.layers:
            out.append({
                "name": l.name, "kind": l.kind, "cin": l.cin, "cout": l.cout,
                "kx": l.kx, "ky": l.ky, "stride": l.stride,
                "relu": l.relu, "bn": l.bn, "bias": l.bias,
                "in_h": l.in_h, "in_w": l.in_w,
                "out_h": l.out_h, "out_w": l.out_w,
                "qidx": l.qidx, "ops": l.ops if l.is_quant else 0,
                "weights_per_channel": l.weights_per_channel if l.is_quant else 0,
                "save_as": l.save_as, "add_from": l.add_from,
                "input_from": l.input_from,
            })
        return out


def build_model(name: str, layers: list[LayerDef], input_shape, n_classes,
                loss="ce") -> ModelDef:
    """Resolve geometry (SAME padding, strides) through the graph."""
    if len(input_shape) == 3:
        h, w, c = input_shape
    else:
        h, w, c = 1, 1, input_shape[0]
    tags: dict[str, tuple] = {}
    qidx = 0
    for l in layers:
        if l.input_from is not None:
            h, w, c = tags[l.input_from]
        l.in_h, l.in_w, l.cin = h, w, c
        if l.kind in ("conv", "dwconv"):
            if l.kind == "dwconv":
                l.cout = c
            h = -(-h // l.stride)   # ceil division == SAME padding
            w = -(-w // l.stride)
            c = l.cout
        elif l.kind == "fc":
            c = l.cout
            h = w = 1
        elif l.kind == "avgpool":
            h = w = 1
            l.cout = c
        elif l.kind == "flatten":
            c = h * w * c
            h = w = 1
            l.cout = c
        elif l.kind in ("add", "tap"):
            l.cout = c
        else:
            raise ValueError(f"unknown layer kind {l.kind}")
        l.out_h, l.out_w = h, w
        if l.is_quant:
            l.qidx = qidx
            qidx += 1
        if l.save_as is not None:
            tags[l.save_as] = (h, w, c)
    md = ModelDef(name, layers, tuple(input_shape), n_classes, loss)
    md.qlayers = [l for l in layers if l.is_quant]
    return md


# ---------------------------------------------------------------------------
# Initialisation.
# ---------------------------------------------------------------------------

def init_params(model: ModelDef, seed: int, mode: str):
    """Returns (params, bn_state, nas) dicts of numpy arrays.

    ``mode``: 'cw' (channel-wise gamma, ours) or 'lw' (layer-wise, EdMIPS).
    """
    rng = np.random.default_rng(seed)
    params: dict[str, np.ndarray] = {}
    bn_state: dict[str, np.ndarray] = {}
    nas: dict[str, np.ndarray] = {}
    np_w = len(PRECISIONS)
    for l in model.layers:
        if not l.is_quant:
            continue
        fan_in = l.weights_per_channel
        std = float(np.sqrt(2.0 / max(fan_in, 1)))
        params[f"{l.name}.w"] = rng.normal(0.0, std, l.weight_shape).astype(np.float32)
        if l.bias:
            params[f"{l.name}.b"] = np.zeros((l.cout,), np.float32)
        if l.bn:
            params[f"{l.name}.bn_scale"] = np.ones((l.cout,), np.float32)
            params[f"{l.name}.bn_bias"] = np.zeros((l.cout,), np.float32)
            bn_state[f"{l.name}.bn_mean"] = np.zeros((l.cout,), np.float32)
            bn_state[f"{l.name}.bn_var"] = np.ones((l.cout,), np.float32)
        params[f"{l.name}.alpha"] = np.asarray(6.0, np.float32)
        rows = l.cout if mode == "cw" else 1
        nas[f"{l.name}.delta"] = np.zeros((np_w,), np.float32)
        nas[f"{l.name}.gamma"] = np.zeros((rows, np_w), np.float32)
    return params, bn_state, nas


# ---------------------------------------------------------------------------
# Forward pass.
# ---------------------------------------------------------------------------

def apply_model(model: ModelDef, params: dict, bn_state: dict,
                assign: dict, x: jax.Array, *, train: bool,
                update_stats, lut: jax.Array):
    """Run the graph.

    ``assign`` maps layer name -> (delta_hat (|P_X|,), gamma_hat (rows,|P_W|))
    — already softmax-ed (search) or one-hot (eval/deploy/warmup).

    Returns ``(out, new_bn_state, reg_size, reg_energy)`` where the regs are
    the summed Eq. (7) / Eq. (8) over all quantized layers (differentiable
    through ``assign``).
    """
    saved: dict[str, jax.Array] = {}
    new_bn = dict(bn_state)
    reg_size = jnp.zeros((), jnp.float32)
    reg_energy = jnp.zeros((), jnp.float32)
    u = update_stats if train else None

    for l in model.layers:
        if l.input_from is not None:
            x = saved[l.input_from]
        if l.kind == "tap":
            pass
        elif l.kind == "avgpool":
            x = jnp.mean(x, axis=(1, 2))
        elif l.kind == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif l.kind == "add":
            x = x + saved[l.add_from]
            if l.relu:
                x = jax.nn.relu(x)
        elif l.is_quant:
            d_hat, g_hat = assign[l.name]
            alpha = params[f"{l.name}.alpha"]
            w = params[f"{l.name}.w"]
            if l.kind == "fc":
                b = params.get(f"{l.name}.b")
                x = nl.mixed_dense(x, w, b, alpha, d_hat, g_hat)
            else:
                x = nl.mixed_conv2d(x, w, alpha, d_hat, g_hat,
                                    l.stride, groups=l.groups)
            if l.bn:
                sc = params[f"{l.name}.bn_scale"]
                bi = params[f"{l.name}.bn_bias"]
                if train:
                    x, nm, nv = nl.batchnorm_train(
                        x, sc, bi,
                        bn_state[f"{l.name}.bn_mean"],
                        bn_state[f"{l.name}.bn_var"], u)
                    new_bn[f"{l.name}.bn_mean"] = nm
                    new_bn[f"{l.name}.bn_var"] = nv
                else:
                    x = nl.batchnorm_apply(
                        x, sc, bi,
                        bn_state[f"{l.name}.bn_mean"],
                        bn_state[f"{l.name}.bn_var"])
            if l.relu and l.add_from is None:
                x = jax.nn.relu(x)
            if l.add_from is not None:
                x = x + saved[l.add_from]
                if l.relu:
                    x = jax.nn.relu(x)
            reg_size = reg_size + nl.reg_size_term(
                g_hat, l.cin if l.kind != "dwconv" else 1, l.kx, l.ky, l.cout)
            reg_energy = reg_energy + nl.reg_energy_term(
                d_hat, g_hat, l.ops, l.cout, lut)
        else:
            raise ValueError(l.kind)
        if l.save_as is not None:
            saved[l.save_as] = x
    return x, new_bn, reg_size, reg_energy
