"""The four MLPerf Tiny benchmark models.

Topologies follow the MLPerf Tiny reference implementations (Banbury et
al. 2021) with the scaling documented in DESIGN.md §5:

  * **IC**  — ResNet-8 (16/32/64, 3 stages), 32x32x3, 10 classes.  Exact
    MLPerf geometry.  Layer naming matches Fig. 4 of the paper: ``c1``,
    ``b<i>c<j>`` for stage convs, ``b<i>sc`` for residual 1x1 shortcuts.
  * **KWS** — DS-CNN small (64ch, 4 depthwise-separable blocks), 49x10x1
    MFCC grid, 12 classes.  Exact MLPerf geometry.
  * **VWW** — MobileNetV1 width 0.25; input scaled 96x96 -> 48x48 for the
    CPU training budget (all 27 quantizable layers preserved), 2 classes.
  * **AD**  — dense autoencoder, 256 -> 128x2 -> 8 -> 128x2 -> 256 (the
    paper's 128-neuron FC width is preserved; input 640 -> 256).
"""

from __future__ import annotations

from .common import LayerDef as L, ModelDef, build_model


def resnet8_ic() -> ModelDef:
    layers = [
        L("c1", "conv", cout=16, kx=3, ky=3, stride=1),
        # stage 1: identity skip
        L("b1_tap", "tap", save_as="b1_in"),
        L("b1c1", "conv", cout=16, kx=3, ky=3, stride=1),
        L("b1c2", "conv", cout=16, kx=3, ky=3, stride=1, relu=True,
          add_from="b1_in"),
        # stage 2: downsample, 1x1 conv skip
        L("b2_tap", "tap", save_as="b2_in"),
        L("b2c1", "conv", cout=32, kx=3, ky=3, stride=2),
        L("b2c2", "conv", cout=32, kx=3, ky=3, stride=1, relu=False,
          save_as="b2_main"),
        L("b2sc", "conv", cout=32, kx=1, ky=1, stride=2, relu=True,
          input_from="b2_in", add_from="b2_main"),
        # stage 3: downsample, 1x1 conv skip
        L("b3_tap", "tap", save_as="b3_in"),
        L("b3c1", "conv", cout=64, kx=3, ky=3, stride=2),
        L("b3c2", "conv", cout=64, kx=3, ky=3, stride=1, relu=False,
          save_as="b3_main"),
        L("b3sc", "conv", cout=64, kx=1, ky=1, stride=2, relu=True,
          input_from="b3_in", add_from="b3_main"),
        L("pool", "avgpool"),
        L("fc", "fc", cout=10, relu=False, bn=False, bias=True),
    ]
    return build_model("ic", layers, (32, 32, 3), 10, "ce")


def dscnn_kws() -> ModelDef:
    layers = [
        L("c1", "conv", cout=64, kx=10, ky=4, stride=2),
    ]
    for i in range(1, 5):
        layers += [
            L(f"dw{i}", "dwconv", kx=3, ky=3, stride=1),
            L(f"pw{i}", "conv", cout=64, kx=1, ky=1, stride=1),
        ]
    layers += [
        L("pool", "avgpool"),
        L("fc", "fc", cout=12, relu=False, bn=False, bias=True),
    ]
    return build_model("kws", layers, (49, 10, 1), 12, "ce")


def mobilenet_vww() -> ModelDef:
    # MobileNetV1 x0.25 channel plan (full-size plan scaled by 1/4).
    plan = [(16, 1), (32, 2), (32, 1), (64, 2), (64, 1),
            (128, 2), (128, 1), (128, 1), (128, 1), (128, 1),
            (128, 1), (256, 2), (256, 1)]
    layers = [L("c1", "conv", cout=8, kx=3, ky=3, stride=2)]
    for i, (cout, s) in enumerate(plan, start=1):
        layers += [
            L(f"dw{i}", "dwconv", kx=3, ky=3, stride=s),
            L(f"pw{i}", "conv", cout=cout, kx=1, ky=1, stride=1),
        ]
    layers += [
        L("pool", "avgpool"),
        L("fc", "fc", cout=2, relu=False, bn=False, bias=True),
    ]
    return build_model("vww", layers, (48, 48, 3), 2, "ce")


def autoencoder_ad() -> ModelDef:
    dims = [128, 128, 8, 128, 128]
    layers = []
    for i, d in enumerate(dims, start=1):
        layers.append(L(f"fc{i}", "fc", cout=d))
    layers.append(L("out", "fc", cout=256, relu=False, bn=False, bias=True))
    return build_model("ad", layers, (256,), 0, "mse")


BENCHMARKS = {
    "ic": resnet8_ic,
    "kws": dscnn_kws,
    "vww": mobilenet_vww,
    "ad": autoencoder_ad,
}


def get_model(name: str) -> ModelDef:
    return BENCHMARKS[name]()
