"""The four MLPerf Tiny benchmark models (scaled — see DESIGN.md §5)."""

from .common import LayerDef, ModelDef, build_model
from .zoo import BENCHMARKS, get_model

__all__ = ["LayerDef", "ModelDef", "build_model", "BENCHMARKS", "get_model"]
