"""L1/L2 performance analysis (the §Perf evidence for EXPERIMENTS.md).

Usage: ``python -m compile.perf_report``

L1 (Pallas): interpret=True gives CPU-numpy timing only — NOT a TPU
proxy — so kernel quality is assessed *structurally*:
  * VMEM working set per BlockSpec tile (must fit the ~16 MiB/core VMEM
    with double-buffering headroom);
  * HBM traffic of the fused kernels vs the naive |P|-copy formulation
    (the paper's PyTorch baseline materialises |P| fake-quantized copies);
  * arithmetic intensity (fake-quant is VPU-bound; the blend adds 2 FLOPs
    per copy per element).

L2 (lowered graphs): HLO op counts of the fused vs naive formulation and
wall-clock of one jitted step on this host (same backend the Rust runtime
executes, so relative changes transfer).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.fake_quant import _MAX_SINGLE_BLOCK, _TILE_COLS, _TILE_ROWS
from .kernels.mixed_weight import mixed_act_pallas, mixed_weight_pallas
from .kernels.ref import mixed_act_ref, mixed_weight_ref
from .models import BENCHMARKS, get_model

P = 3  # |P_W| = |P_X|


def tile_of(rows: int, cols: int):
    if rows * cols <= _MAX_SINGLE_BLOCK:
        return rows, cols
    return min(_TILE_ROWS, rows), min(_TILE_COLS, cols)


def l1_report():
    print("=" * 72)
    print("L1 — Pallas kernel structural analysis (per benchmark layer)")
    print("=" * 72)
    print(f"{'bench/layer':<18}{'shape':<16}{'tile':<12}"
          f"{'VMEM KB':>9}{'naiveMB':>9}{'fusedMB':>9}{'saving':>8}")
    for bench in BENCHMARKS:
        m = get_model(bench)
        for l in m.qlayers:
            cout = l.cout
            k = l.weights_per_channel
            tr, tc = tile_of(cout, k)
            # fused mixed-weight kernel: w tile + gamma tile + out tile
            vmem = (tr * tc * 2 + tr * P) * 4 / 1024
            # HBM bytes: naive = read W, write P copies, read P copies + gamma
            n_el = cout * k * 4
            naive = (n_el * (1 + 2 * P) + cout * P * 4) / 1e6
            fused = (n_el * 2 + cout * P * 4) / 1e6
            print(f"{bench + '/' + l.name:<18}{str((cout, k)):<16}"
                  f"{str((tr, tc)):<12}{vmem:>9.1f}{naive:>9.3f}"
                  f"{fused:>9.3f}{naive / fused:>7.2f}x")
        # activation blend for the largest activation
        big = max(m.qlayers, key=lambda l: l.in_h * l.in_w * l.cin)
        n = 32 * big.in_h * big.in_w * big.cin  # batch 32
        rows = n // 128 if n % 128 == 0 else 1
        cols = 128 if n % 128 == 0 else n
        tr, tc = tile_of(rows, cols)
        vmem = (tr * tc * 2) * 4 / 1024
        naive = n * 4 * (1 + 2 * P) / 1e6
        fused = n * 4 * 2 / 1e6
        print(f"{bench + '/act(' + big.name + ')':<18}{str((rows, cols)):<16}"
              f"{str((tr, tc)):<12}{vmem:>9.1f}{naive:>9.3f}"
              f"{fused:>9.3f}{naive / fused:>7.2f}x")


def count_hlo_ops(fn, *args) -> tuple[int, int]:
    lowered = jax.jit(fn).lower(*args)
    txt = lowered.compile().as_text()
    fusions = txt.count("fusion")
    lines = len(txt.splitlines())
    return lines, fusions


def time_jit(fn, *args, iters=20) -> float:
    f = jax.jit(fn)
    r = f(*args)
    jax.block_until_ready(r)
    t0 = time.perf_counter()
    for _ in range(iters):
        r = f(*args)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / iters * 1e3


def l2_report():
    print()
    print("=" * 72)
    print("L2 — fused (Pallas single-pass) vs naive (|P|-copy ref) lowering")
    print("=" * 72)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(0, 0.2, (64, 576)).astype(np.float32))
    g = jax.nn.softmax(jnp.asarray(rng.normal(0, 1, (64, 3)).astype(np.float32)))
    x = jnp.asarray(np.abs(rng.normal(0.8, 0.5, (32, 32, 32, 16))).astype(np.float32))
    a = jnp.float32(6.0)
    d = jnp.array([0.2, 0.5, 0.3], jnp.float32)

    for name, fused, naive, args in [
        ("mixed_weight (64x576)", mixed_weight_pallas, mixed_weight_ref, (w, g)),
        ("mixed_act (32x32x32x16)", mixed_act_pallas,
         lambda x_, a_, d_: mixed_act_ref(x_, a_, d_), (x, a, d)),
    ]:
        tf = time_jit(lambda *z: fused(*z), *args)
        tn = time_jit(lambda *z: naive(*z), *args)
        print(f"  {name:<26} fused {tf:7.3f} ms | naive {tn:7.3f} ms "
              f"| speedup {tn / tf:4.2f}x (CPU; structural HBM saving is the "
              f"TPU-relevant number)")

    # gradient path (the training hot loop)
    def loss_fused(w, g):
        return jnp.sum(mixed_weight_pallas(w, g) ** 2)

    def loss_naive(w, g):
        return jnp.sum(mixed_weight_ref(w, g) ** 2)

    tf = time_jit(jax.grad(loss_fused, argnums=(0, 1)), w, g)
    tn = time_jit(jax.grad(loss_naive, argnums=(0, 1)), w, g)
    print(f"  {'mixed_weight fwd+bwd':<26} fused {tf:7.3f} ms | naive "
          f"{tn:7.3f} ms | speedup {tn / tf:4.2f}x")


def main():
    l1_report()
    l2_report()


if __name__ == "__main__":
    main()
