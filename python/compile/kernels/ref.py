"""Pure-jnp reference oracle for every Pallas kernel in this package.

These are the *semantics* the kernels must match bit-for-bit (float32).
pytest (``python/tests/test_kernels_vs_ref.py``) sweeps shapes and dtypes
with hypothesis and asserts ``allclose`` between each kernel and its oracle.

Nothing here is used at runtime; kernels call into the same math via their
tiled Pallas implementations and the training graphs call the kernels.
"""

from __future__ import annotations

import jax.numpy as jnp


def pact_fake_quant_ref(x: jnp.ndarray, alpha: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """PACT fake quantization, Eq. (1) with [alpha_t, beta_t] = [0, alpha]."""
    levels = (1 << n_bits) - 1
    a = jnp.maximum(alpha, 1e-6)
    eps = a / levels
    xc = jnp.clip(x, 0.0, a)
    return jnp.round(xc / eps) * eps


def weight_fake_quant_ref(w2d: jnp.ndarray, n_bits: int) -> jnp.ndarray:
    """Per-row symmetric weight fake quantization (w2d: (Cout, K))."""
    levels = (1 << (n_bits - 1)) - 1
    amax = jnp.max(jnp.abs(w2d), axis=1, keepdims=True)
    s = jnp.maximum(amax, 1e-8) / levels
    q = jnp.clip(jnp.round(w2d / s), -levels, levels)
    return q * s


def mixed_weight_ref(w2d: jnp.ndarray, gamma_hat: jnp.ndarray,
                     precisions=(2, 4, 8)) -> jnp.ndarray:
    """Effective weight tensor, Eq. (5).

    ``w2d``:       (Cout, K) float weights (shared storage).
    ``gamma_hat``: (Cout, |P_W|) softmax-ed NAS parameters (rows sum to 1),
                   or (1, |P_W|) for the layer-wise (EdMIPS) mode.
    Returns (Cout, K): ``sum_p gamma_hat[:, p:p+1] * fq(w2d, p)``.
    """
    out = jnp.zeros_like(w2d)
    for j, p in enumerate(precisions):
        out = out + gamma_hat[:, j:j + 1] * weight_fake_quant_ref(w2d, p)
    return out


def mixed_act_ref(x: jnp.ndarray, alpha: jnp.ndarray, delta_hat: jnp.ndarray,
                  precisions=(2, 4, 8)) -> jnp.ndarray:
    """Effective activation tensor, Eq. (4).

    ``delta_hat``: (|P_X|,) softmax-ed NAS parameters (sums to 1).
    """
    out = jnp.zeros_like(x)
    for j, p in enumerate(precisions):
        out = out + delta_hat[j] * pact_fake_quant_ref(x, alpha, p)
    return out


def int_gemm_ref(a_int: jnp.ndarray, b_int: jnp.ndarray) -> jnp.ndarray:
    """Integer GEMM oracle: (M,K) x (K,N) matmul with exact accumulation.

    Inputs are float arrays holding exact small integers (the HLO path keeps
    everything in f32; values are integral so f32 accumulation is exact for
    the magnitudes used by <=8-bit operands and K <= 2^15).
    """
    return jnp.dot(a_int, b_int, precision="highest")
