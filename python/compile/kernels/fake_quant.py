"""Pallas kernels for PACT fake-quantization (the NAS hot-spot, Eq. (1)).

TPU-shaped design
-----------------
The paper's PyTorch implementation materialises ``|P|`` fake-quantized copies
of every tensor on every forward pass (its stated memory/compute overhead).
On a TPU-like memory hierarchy that is an HBM-bandwidth problem, not a FLOP
problem: fake-quant is pure VPU elementwise work.  These kernels therefore:

  * fuse *all* |P| fake-quantizations and the NAS blend into a single pass
    over the tensor (see ``mixed_weight.py`` for the weight analogue);
  * tile the tensor with ``BlockSpec``: whole-array blocks while the
    operand fits the per-core working-set budget (every benchmark layer
    does), falling back to (8 x 128)-multiple row tiles above it — the
    VPU register shape, so the TPU lowering keeps lanes full;
  * keep scalars (``alpha``, blend coefficients) in (1, n) blocks
    broadcast to every tile.

``interpret=True`` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls; interpret-mode lowers to plain HLO, which both the build-time
pytest checks and the Rust runtime execute.  Real-TPU perf is *estimated*
from the VMEM footprint / MXU-VPU utilisation in DESIGN.md §Perf.

Gradients: the kernels are wrapped in ``jax.custom_vjp`` (STE / PACT rules,
same as ``quantlib``), so the training graphs can call them directly and
the backward pass is plain fused-elementwise XLA.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# VPU-register-shaped tile (sublane x lane) used above the single-block cap.
_TILE_ROWS = 256
_TILE_COLS = 128

# Whole-array blocks below this element count (all benchmark-model tensors
# qualify; the tiled path exists for larger deployments and is exercised
# directly by the pytest suite).
_MAX_SINGLE_BLOCK = 1 << 22


def _tiles(n: int, t: int) -> int:
    return pl.cdiv(n, t)


def _as2d(x: jax.Array):
    """Collapse to 2D: lanes = trailing 128 when possible, else last dim."""
    if x.ndim == 2:
        return x, x.shape
    flat = x.reshape(-1)
    n = flat.shape[0]
    if n % _TILE_COLS == 0:
        return flat.reshape(n // _TILE_COLS, _TILE_COLS), x.shape
    return flat.reshape(1, n), x.shape


def _elementwise_call(kernel, x2d: jax.Array, *scalars):
    """Launch an elementwise kernel over ``x2d`` with broadcast scalars.

    ``scalars`` are small (1, k) arrays fetched whole into every block.
    """
    r, c = x2d.shape
    if r * c <= _MAX_SINGLE_BLOCK:
        grid = (1, 1)
        blk = (r, c)
    else:
        blk = (min(_TILE_ROWS, r), min(_TILE_COLS, c))
        grid = (_tiles(r, blk[0]), _tiles(c, blk[1]))
    in_specs = [pl.BlockSpec(blk, lambda i, j: (i, j))]
    for s in scalars:
        sshape = s.shape
        in_specs.append(pl.BlockSpec(sshape, lambda i, j: (0, 0)))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec(blk, lambda i, j: (i, j)),
        interpret=True,
    )(x2d, *scalars)


# ---------------------------------------------------------------------------
# PACT activation fake-quant kernel (single precision).
# ---------------------------------------------------------------------------

def _pact_kernel(x_ref, a_ref, o_ref, *, levels: float):
    a = jnp.maximum(a_ref[0, 0], 1e-6)
    eps = a / levels
    xc = jnp.clip(x_ref[...], 0.0, a)
    o_ref[...] = jnp.round(xc / eps) * eps


def _make_pact_pallas():
    @functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
    def _f(x, alpha, n_bits):
        x2d, shape = _as2d(x)
        levels = float((1 << n_bits) - 1)
        y = _elementwise_call(
            functools.partial(_pact_kernel, levels=levels),
            x2d, jnp.reshape(alpha, (1, 1)))
        return y.reshape(shape)

    def fwd(x, alpha, n_bits):
        return _f(x, alpha, n_bits), (x, alpha)

    def bwd(n_bits, res, g):
        x, alpha = res
        a = jnp.maximum(alpha, 1e-6)
        in_range = jnp.logical_and(x >= 0.0, x <= a)
        gx = jnp.where(in_range, g, 0.0)
        galpha = jnp.sum(jnp.where(x > a, g, 0.0))
        return gx, galpha.reshape(jnp.shape(alpha)).astype(g.dtype)

    _f.defvjp(fwd, bwd)
    return _f


pact_fake_quant_pallas = _make_pact_pallas()
"""``pact_fake_quant_pallas(x, alpha, n_bits)`` — tiled PACT fake quant.

Any-rank ``x``, scalar array ``alpha``, static int ``n_bits``.  Forward runs
the Pallas kernel; backward is the analytic STE/PACT rule.
"""


# ---------------------------------------------------------------------------
# Per-channel weight fake-quant kernel (rows = output channels).
# ---------------------------------------------------------------------------

def _wfq_kernel(w_ref, o_ref, *, levels: float):
    w = w_ref[...]
    amax = jnp.max(jnp.abs(w), axis=1, keepdims=True)
    s = jnp.maximum(amax, 1e-8) / levels
    q = jnp.clip(jnp.round(w / s), -levels, levels)
    o_ref[...] = q * s


def rowwise_call(kernel, w2d: jax.Array, *row_blocks):
    """Launch a row-wise kernel: blocks hold *entire rows* (full K) so
    per-channel reductions never cross block boundaries.  ``row_blocks``
    are per-row side inputs (rows x k_i) tiled with the same row split."""
    rows, k = w2d.shape
    if rows * k <= _MAX_SINGLE_BLOCK:
        br = rows
        grid = (1,)
    else:
        br = min(_TILE_ROWS, rows)
        grid = (_tiles(rows, br),)
    in_specs = [pl.BlockSpec((br, k), lambda i: (i, 0))]
    for rb in row_blocks:
        cols = rb.shape[1]
        in_specs.append(pl.BlockSpec((br, cols), lambda i: (i, 0)))
    return pl.pallas_call(
        kernel,
        out_shape=jax.ShapeDtypeStruct(w2d.shape, w2d.dtype),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((br, k), lambda i: (i, 0)),
        interpret=True,
    )(w2d, *row_blocks)


def _make_weight_fq_pallas():
    @functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
    def _f(w2d, n_bits):
        levels = float((1 << (n_bits - 1)) - 1)
        return rowwise_call(
            functools.partial(_wfq_kernel, levels=levels), w2d)

    def fwd(w2d, n_bits):
        return _f(w2d, n_bits), ()

    def bwd(n_bits, res, g):
        return (g,)  # STE

    _f.defvjp(fwd, bwd)
    return _f


weight_fake_quant_pallas = _make_weight_fq_pallas()
"""Per-channel symmetric weight fake quant over (Cout, K); STE backward."""
