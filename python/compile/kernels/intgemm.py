"""Tiled Pallas integer-GEMM kernel (deployment cross-check path).

The MPIC simulator in ``rust/src/mpic/`` executes deployed layers as
integer GEMMs (im2col).  To cross-validate it against the HLO world, the
``infer_deployed`` artifact runs the same integer contraction through this
kernel: operands are f32 tensors holding exact small integers (quantized
activations in [0, 2^px - 1], weights in [-(2^(pw-1)-1), +]), accumulation
is exact in f32 for all supported magnitudes (|acc| < 2^24 guaranteed by
8-bit operands and K <= 2^9 in every benchmark model).

MXU-shaped tiling: (TM x TK) @ (TK x TN) blocks with TM = TN = 128 when the
problem is big enough, K kept whole per block (all benchmark layers have
K = Cin*Kx*Ky <= 576, i.e. at most 4.5 MXU passes of 128).  The grid walks
output tiles; each output tile is computed by one kernel invocation, so no
cross-block accumulator is needed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_TM = 128
_TN = 128


def _gemm_kernel(a_ref, b_ref, o_ref):
    o_ref[...] = jnp.dot(a_ref[...], b_ref[...], precision="highest")


def int_gemm_pallas(a: jax.Array, b: jax.Array) -> jax.Array:
    """(M,K) @ (K,N) with exact f32 accumulation of integer-valued operands."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"contraction mismatch {a.shape} @ {b.shape}"
    tm = _TM if m > _TM else m
    tn = _TN if n > _TN else n
    return pl.pallas_call(
        _gemm_kernel,
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        grid=(pl.cdiv(m, tm), pl.cdiv(n, tn)),
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        interpret=True,
    )(a.astype(jnp.float32), b.astype(jnp.float32))
