"""Fused Pallas kernels for the NAS blends: Eq. (4) and Eq. (5).

These are the paper's *added* compute cost over plain QAT: every forward
pass must fake-quantize each tensor at every precision in ``P`` and blend
the copies with the softmax-ed NAS coefficients.

A naive implementation (the PyTorch original, and ``ref.py``) materialises
|P| full-size copies in HBM.  Both kernels here fuse the whole blend into
a **single pass**:

``mixed_weight_pallas`` (Eq. 5), per output-channel row i:
    amax_i = max|W_i|                         (one reduction, reused)
    out_i  = sum_p gamma_hat[i,p] * clip(round(W_i/s_ip)) * s_ip,
             s_ip = amax_i / (2^(p-1)-1)

``mixed_act_pallas`` (Eq. 4), elementwise:
    out = sum_p delta_hat[p] * pact_fq(x, alpha, p)

so each tensor is read HBM->VMEM once and written once — a (|P|+1)x
reduction in traffic vs the naive path (the §Perf L1 measurement).

Backward (custom VJP, weight-sharing exactly as §III-A):
  * STE through the quantizer; since softmax rows sum to 1,
    ``dL/dW = g`` and ``dL/dx = g * 1[0 <= x <= alpha]``;
  * ``dL/dgamma[i,p] = <g_i, fq(W_i,p)>`` and ``dL/ddelta[p] =
    <g, fq(x,p)>`` — recomputed from the single stored float tensor, so
    no quantized copies survive the forward pass;
  * PACT alpha rule: saturated elements pass their cotangent to alpha.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .fake_quant import _as2d, _elementwise_call, rowwise_call
from .ref import pact_fake_quant_ref, weight_fake_quant_ref

PRECISIONS = (2, 4, 8)


# ---------------------------------------------------------------------------
# Eq. (5): fused per-channel weight blend.
# ---------------------------------------------------------------------------

def _mixed_weight_kernel(w_ref, g_ref, o_ref, *, precisions):
    w = w_ref[...]
    gam = g_ref[...]
    amax = jnp.maximum(jnp.max(jnp.abs(w), axis=1, keepdims=True), 1e-8)
    acc = jnp.zeros_like(w)
    for j, p in enumerate(precisions):
        levels = float((1 << (p - 1)) - 1)
        s = amax / levels
        q = jnp.clip(jnp.round(w / s), -levels, levels) * s
        acc = acc + gam[:, j:j + 1] * q
    o_ref[...] = acc


def _make_mixed_weight():
    @jax.custom_vjp
    def _f(w2d, gamma_hat):
        return rowwise_call(
            functools.partial(_mixed_weight_kernel,
                              precisions=PRECISIONS),
            w2d, gamma_hat)

    def fwd(w2d, gamma_hat):
        return _f(w2d, gamma_hat), (w2d,)

    def bwd(res, g):
        (w2d,) = res
        # STE: sum_p gamma_hat[i,p] == 1  =>  dL/dW = g.
        gw = g
        # dL/dgamma_hat[i,p] = <g_i, fq(W_i, p)> — recomputed, not stored.
        cols = [jnp.sum(g * weight_fake_quant_ref(w2d, p), axis=1)
                for p in PRECISIONS]
        ggam = jnp.stack(cols, axis=1)
        return gw, ggam

    _f.defvjp(fwd, bwd)
    return _f


mixed_weight_pallas = _make_mixed_weight()
"""``mixed_weight_pallas(w2d, gamma_hat)`` — fused Eq. (5).

``w2d``: (Cout, K) float weights; ``gamma_hat``: (Cout, |P_W|) rows summing
to 1 (pre-broadcast layer-wise rows for the EdMIPS mode).
"""


# ---------------------------------------------------------------------------
# Eq. (4): fused activation blend.
# ---------------------------------------------------------------------------

def _mixed_act_kernel(x_ref, a_ref, d_ref, o_ref, *, precisions):
    a = jnp.maximum(a_ref[0, 0], 1e-6)
    x = x_ref[...]
    xc = jnp.clip(x, 0.0, a)
    acc = jnp.zeros_like(x)
    for j, p in enumerate(precisions):
        levels = float((1 << p) - 1)
        eps = a / levels
        acc = acc + d_ref[0, j] * (jnp.round(xc / eps) * eps)
    o_ref[...] = acc


def _make_mixed_act():
    @jax.custom_vjp
    def _f(x, alpha, delta_hat):
        x2d, shape = _as2d(x)
        y = _elementwise_call(
            functools.partial(_mixed_act_kernel, precisions=PRECISIONS),
            x2d, jnp.reshape(alpha, (1, 1)),
            jnp.reshape(delta_hat, (1, -1)))
        return y.reshape(shape)

    def fwd(x, alpha, delta_hat):
        return _f(x, alpha, delta_hat), (x, alpha, delta_hat)

    def bwd(res, g):
        x, alpha, delta_hat = res
        a = jnp.maximum(alpha, 1e-6)
        dsum = jnp.sum(delta_hat)
        in_range = jnp.logical_and(x >= 0.0, x <= a)
        gx = jnp.where(in_range, g, 0.0) * dsum
        galpha = (jnp.sum(jnp.where(x > a, g, 0.0)) * dsum) \
            .reshape(jnp.shape(alpha)).astype(g.dtype)
        gdelta = jnp.stack(
            [jnp.sum(g * pact_fake_quant_ref(x, alpha, p))
             for p in PRECISIONS]).astype(delta_hat.dtype)
        return gx, galpha, gdelta.reshape(jnp.shape(delta_hat))

    _f.defvjp(fwd, bwd)
    return _f


mixed_act_pallas = _make_mixed_act()
"""``mixed_act_pallas(x, alpha, delta_hat)`` — fused Eq. (4).

Any-rank ``x``; ``delta_hat`` is a length-|P_X| vector summing to 1.
Single Pallas pass; analytic STE/PACT backward differentiable in ``x``,
``alpha`` and ``delta_hat``.
"""
