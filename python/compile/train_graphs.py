"""The six AOT-exported compute graphs per benchmark (DESIGN.md §3).

All training state lives in the Rust coordinator and is threaded through
every call as flat tensor lists, so the graphs are pure functions:

  * ``train_w_hard``     — one QAT step with a *hard* (one-hot) precision
    assignment.  Serves Alg. 1's warmup (8-bit one-hots), its fine-tuning
    phase (argmax one-hots), and every fixed-precision ``wNxM`` baseline.
  * ``search_theta``     — Alg. 1 line 5: update NAS parameters theta by
    Adam on ``L_T + lambda_s * L_size + lambda_e * L_energy`` (Eq. 2/7/8).
  * ``search_w``         — Alg. 1 line 7: update weights (incl. PACT
    alphas, BN affine) by Adam on ``L_T`` with the *soft* assignment.
  * ``eval_hard``        — loss + score under a hard assignment with
    frozen BN running stats (validation / early-stop / final scoring).
  * ``infer_hard``       — logits (or reconstructions) only; deployment
    cross-check against the MPIC simulator.

Conventions (mirrored in manifest.json and rust/src/runtime):
  * parameter, BN-state and NAS tensors travel in the insertion order of
    ``models.common.init_params`` (recorded by name in the manifest);
  * hard assignments are always per-channel ``(Cout, |P_W|)`` one-hot
    matrices plus ``(|P_X|,)`` activation one-hots (layer-wise results are
    just broadcast rows);
  * scalars (lr, tau, lambdas, step counter, flags) are f32 0-d tensors.

Adam is the optimizer for both W and theta (lr passed per call so the Rust
side owns the schedule).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .energy_lut import energy_lut
from .models.common import ModelDef, apply_model, init_params
from .quantlib import PRECISIONS, softmax_temperature

ADAM_B1 = 0.9
ADAM_B2 = 0.999
ADAM_EPS = 1e-8
NP = len(PRECISIONS)


# ---------------------------------------------------------------------------
# Loss / metric.
# ---------------------------------------------------------------------------

def task_loss(model: ModelDef, out: jax.Array, y: jax.Array):
    """Returns (scalar loss, scalar metric).

    Classification: mean CE, metric = #correct in batch.
    Reconstruction (AD): mean MSE, metric = mean per-sample MSE.
    """
    if model.loss == "ce":
        logz = jax.nn.log_softmax(out, axis=-1)
        onehot = jax.nn.one_hot(y, model.n_classes, dtype=out.dtype)
        loss = -jnp.mean(jnp.sum(onehot * logz, axis=-1))
        metric = jnp.sum((jnp.argmax(out, axis=-1) == y).astype(jnp.float32))
        return loss, metric
    # mse: y is the target (== input for the autoencoder)
    per_sample = jnp.mean((out - y) ** 2, axis=-1)
    loss = jnp.mean(per_sample)
    return loss, loss


def per_sample_score(model: ModelDef, out: jax.Array, y: jax.Array):
    """Per-sample statistic for eval: 1/0 correctness or reconstruction MSE
    (the Rust side turns AD reconstruction errors into AUC)."""
    if model.loss == "ce":
        return (jnp.argmax(out, axis=-1) == y).astype(jnp.float32)
    return jnp.mean((out - y) ** 2, axis=-1)


# ---------------------------------------------------------------------------
# Adam (flat-list states).
# ---------------------------------------------------------------------------

def adam_update(params, grads, m, v, t, lr):
    """One Adam step over flat lists; ``t`` is the 0-based step count."""
    t1 = t + 1.0
    c1 = 1.0 - ADAM_B1 ** t1
    c2 = 1.0 - ADAM_B2 ** t1
    new_p, new_m, new_v = [], [], []
    for p, g, mi, vi in zip(params, grads, m, v):
        mi = ADAM_B1 * mi + (1.0 - ADAM_B1) * g
        vi = ADAM_B2 * vi + (1.0 - ADAM_B2) * (g * g)
        step = lr * (mi / c1) / (jnp.sqrt(vi / c2) + ADAM_EPS)
        new_p.append(p - step)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


# ---------------------------------------------------------------------------
# Graph builders.
# ---------------------------------------------------------------------------

class GraphSet:
    """All lowered-function builders for one (benchmark, mode) pair."""

    def __init__(self, model: ModelDef, mode: str, seed: int = 0):
        assert mode in ("cw", "lw")
        self.model = model
        self.mode = mode
        p0, b0, n0 = init_params(model, seed, mode)
        self.pnames = list(p0)
        self.bnames = list(b0)
        self.nnames = list(n0)
        self.pshapes = {k: v.shape for k, v in p0.items()}
        self.bshapes = {k: v.shape for k, v in b0.items()}
        self.nshapes = {k: v.shape for k, v in n0.items()}
        self.lut = jnp.asarray(energy_lut())
        self.qnames = [l.name for l in model.qlayers]

    # -- plumbing -----------------------------------------------------------

    def _pdict(self, plist):
        return dict(zip(self.pnames, plist))

    def _bdict(self, blist):
        return dict(zip(self.bnames, blist))

    def _ndict(self, nlist):
        return dict(zip(self.nnames, nlist))

    def _soft_assign(self, nas: dict, tau):
        """Softmax-with-temperature assignment (Eq. 3) for every layer."""
        assign = {}
        for q in self.qnames:
            d = softmax_temperature(nas[f"{q}.delta"], tau)
            g = softmax_temperature(nas[f"{q}.gamma"], tau)
            assign[q] = (d, g)
        return assign

    def _hard_assign(self, hard_list):
        """hard_list alternates [delta_oh_0, gamma_oh_0, delta_oh_1, ...]."""
        assign = {}
        for i, q in enumerate(self.qnames):
            assign[q] = (hard_list[2 * i], hard_list[2 * i + 1])
        return assign

    def hard_shapes(self):
        """Shapes of the hard-assignment inputs (always per-channel)."""
        out = []
        for l in self.model.qlayers:
            out.append(("delta_oh." + l.name, (NP,)))
            out.append(("gamma_oh." + l.name, (l.cout, NP)))
        return out

    # -- graphs -------------------------------------------------------------

    def train_w_hard(self, plist, blist, mlist, vlist, t, hard_list, x, y, lr):
        """QAT step with hard assignment (warmup / finetune / baselines)."""
        model = self.model

        def loss_fn(plist_):
            params = self._pdict(plist_)
            bn = self._bdict(blist)
            assign = self._hard_assign(hard_list)
            out, new_bn, _, _ = apply_model(
                model, params, bn, assign, x,
                train=True, update_stats=jnp.float32(1.0), lut=self.lut)
            loss, metric = task_loss(model, out, y)
            return loss, (new_bn, metric)

        (loss, (new_bn, metric)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(list(plist))
        new_p, new_m, new_v = adam_update(plist, grads, mlist, vlist, t, lr)
        new_blist = [new_bn[k] for k in self.bnames]
        return tuple(new_p) + tuple(new_blist) + tuple(new_m) + tuple(new_v) \
            + (loss, metric)

    def search_theta(self, plist, blist, nlist, mlist, vlist, t, x, y,
                     tau, lam_size, lam_energy, lr, act_freeze):
        """Alg. 1 line 5: Adam on theta for L_T + lambda * L_R.

        ``act_freeze`` (0/1): masks delta gradients (size-target runs pin
        activations to 8 bit).  BN running stats are NOT updated here.
        """
        model = self.model

        def loss_fn(nlist_):
            params = self._pdict(plist)
            bn = self._bdict(blist)
            nas = self._ndict(nlist_)
            assign = self._soft_assign(nas, tau)
            out, _, reg_s, reg_e = apply_model(
                model, params, bn, assign, x,
                train=True, update_stats=jnp.float32(0.0), lut=self.lut)
            loss, _ = task_loss(model, out, y)
            total = loss + lam_size * reg_s + lam_energy * reg_e
            return total, (loss, reg_s, reg_e)

        (_, (loss, reg_s, reg_e)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(list(nlist))
        # mask activation (delta) gradients when the search is size-only
        masked = []
        for name, g in zip(self.nnames, grads):
            if name.endswith(".delta"):
                masked.append(g * (1.0 - act_freeze))
            else:
                masked.append(g)
        new_n, new_m, new_v = adam_update(nlist, masked, mlist, vlist, t, lr)
        return tuple(new_n) + tuple(new_m) + tuple(new_v) \
            + (loss, reg_s, reg_e)

    def search_w(self, plist, blist, nlist, mlist, vlist, t, x, y, tau, lr):
        """Alg. 1 line 7: Adam on W for L_T with the soft assignment."""
        model = self.model

        def loss_fn(plist_):
            params = self._pdict(plist_)
            bn = self._bdict(blist)
            nas = self._ndict(nlist)
            assign = self._soft_assign(nas, tau)
            out, new_bn, _, _ = apply_model(
                model, params, bn, assign, x,
                train=True, update_stats=jnp.float32(1.0), lut=self.lut)
            loss, metric = task_loss(model, out, y)
            return loss, (new_bn, metric)

        (loss, (new_bn, metric)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(list(plist))
        new_p, new_m, new_v = adam_update(plist, grads, mlist, vlist, t, lr)
        new_blist = [new_bn[k] for k in self.bnames]
        return tuple(new_p) + tuple(new_blist) + tuple(new_m) + tuple(new_v) \
            + (loss, metric)

    def eval_hard(self, plist, blist, hard_list, x, y):
        """Frozen-BN evaluation under a hard assignment.

        Returns (loss, metric, per_sample) — per_sample feeds the Rust AUC
        computation for AD and per-class accounting for the classifiers.
        """
        model = self.model
        params = self._pdict(plist)
        bn = self._bdict(blist)
        assign = self._hard_assign(hard_list)
        out, _, reg_s, reg_e = apply_model(
            model, params, bn, assign, x,
            train=False, update_stats=None, lut=self.lut)
        loss, metric = task_loss(model, out, y)
        return loss, metric, per_sample_score(model, out, y), reg_s, reg_e

    def infer_hard(self, plist, blist, hard_list, x):
        """Deployment-path forward (logits / reconstructions)."""
        model = self.model
        params = self._pdict(plist)
        bn = self._bdict(blist)
        assign = self._hard_assign(hard_list)
        out, _, _, _ = apply_model(
            model, params, bn, assign, x,
            train=False, update_stats=None, lut=self.lut)
        return out
