"""Mixed-precision NAS layers (Eq. (4)–(6)) and their cost terms (Eq. (7)–(8)).

Every quantized layer (Conv2D, depthwise Conv2D, FC) follows the paper's
recipe:

  1. the input activation ``X`` is blended from its ``|P_X|`` PACT
     fake-quantized copies by the layer's softmax-ed ``delta_hat`` (Eq. 4);
  2. the weight tensor is blended *per output channel* from its ``|P_W|``
     fake-quantized copies by ``gamma_hat`` (Eq. 5) — rows of ``gamma_hat``
     are per-channel in the channel-wise mode (ours) and a single broadcast
     row in the layer-wise mode (EdMIPS baseline);
  3. an ordinary convolution / matmul consumes the effective tensors (Eq. 6).

Both blends run through the fused Pallas kernels in ``kernels/``.

The layer also returns its two differentiable cost terms:
  * ``reg_size``  — Eq. (7): effective weight bits;
  * ``reg_energy``— Eq. (8): ops x LUT-expected energy/OP. ``Omega`` in the
    paper is the layer's total MAC count; the inner double sum is an
    *average over channels* of the expected energy/OP, so we scale by
    ``Omega / C_out`` (each channel produces ``Omega / C_out`` of the ops).

Batch-norm here is a plain explicit implementation (folded into the requant
scales at deployment by ``rust/src/deploy/``); running stats are threaded
through the training graphs as explicit state tensors.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .kernels.fake_quant import pact_fake_quant_pallas
from .kernels.mixed_weight import mixed_weight_pallas, mixed_act_pallas
from .quantlib import PRECISIONS

BN_EPS = 1e-3
BN_MOMENTUM = 0.9


# ---------------------------------------------------------------------------
# Cost terms.
# ---------------------------------------------------------------------------

def reg_size_term(gamma_hat: jax.Array, cin: int, kx: int, ky: int,
                  cout: int, precisions=PRECISIONS) -> jax.Array:
    """Eq. (7) for one layer: effective number of weight bits.

    ``gamma_hat`` is (Cout, |P_W|) or (1, |P_W|); the layer-wise row is
    weighted by ``cout`` so both modes measure the same quantity.
    """
    pvec = jnp.asarray(precisions, dtype=jnp.float32)
    per_row_bits = jnp.sum(gamma_hat * pvec[None, :], axis=1)  # (rows,)
    if gamma_hat.shape[0] == 1:
        total_rows = per_row_bits[0] * cout
    else:
        total_rows = jnp.sum(per_row_bits)
    return float(cin * kx * ky) * total_rows


def reg_energy_term(delta_hat: jax.Array, gamma_hat: jax.Array,
                    ops: float, cout: int, lut: jax.Array,
                    precisions=PRECISIONS) -> jax.Array:
    """Eq. (8) for one layer.

    ``lut`` is the (|P_X|, |P_W|) energy/OP table ``C(p_x, p_w)`` profiled
    from the MPIC model (single source of truth: emitted into the manifest
    and mirrored by ``rust/src/energy/lut.rs``).  The inner sums compute the
    channel-expectation of energy/OP; each channel accounts for
    ``ops / cout`` MACs.
    """
    # expected energy per op for each channel row: (rows,)
    # e_row_i = sum_px delta_px * sum_pw gamma_i_pw * lut[px, pw]
    per_px = gamma_hat @ lut.T          # (rows, |P_X|)
    e_row = per_px @ delta_hat          # (rows,)
    if gamma_hat.shape[0] == 1:
        expected = e_row[0] * cout
    else:
        expected = jnp.sum(e_row)
    return (float(ops) / float(cout)) * expected


# ---------------------------------------------------------------------------
# Batch norm.
# ---------------------------------------------------------------------------

def batchnorm_apply(x: jax.Array, scale: jax.Array, bias: jax.Array,
                    mean: jax.Array, var: jax.Array) -> jax.Array:
    inv = scale * jax.lax.rsqrt(var + BN_EPS)
    return (x - mean) * inv + bias


def batchnorm_train(x: jax.Array, scale: jax.Array, bias: jax.Array,
                    run_mean: jax.Array, run_var: jax.Array,
                    update_stats: jax.Array):
    """Batch-stat BN; returns (y, new_run_mean, new_run_var).

    ``update_stats`` is a 0/1 scalar: theta-only steps keep running stats
    frozen (they train NAS parameters on a 20% split, Alg. 1 line 5).
    """
    axes = tuple(range(x.ndim - 1))
    mean = jnp.mean(x, axis=axes)
    var = jnp.var(x, axis=axes)
    y = batchnorm_apply(x, scale, bias, mean, var)
    m = BN_MOMENTUM
    new_mean = run_mean * m + mean * (1.0 - m)
    new_var = run_var * m + var * (1.0 - m)
    u = update_stats
    return (y,
            u * new_mean + (1.0 - u) * run_mean,
            u * new_var + (1.0 - u) * run_var)


# ---------------------------------------------------------------------------
# Mixed-precision layers.
# ---------------------------------------------------------------------------

def effective_act(x: jax.Array, alpha: jax.Array, delta_hat: jax.Array) -> jax.Array:
    """Eq. (4) — blend of PACT fake-quantized copies of the input."""
    return mixed_act_pallas(x, alpha, delta_hat)


def effective_weight(w: jax.Array, gamma_hat: jax.Array) -> jax.Array:
    """Eq. (5) — per-channel blend; ``w`` is (Cout, ...) any layout."""
    cout = w.shape[0]
    gh = gamma_hat
    if gh.shape[0] == 1 and cout != 1:
        gh = jnp.broadcast_to(gh, (cout, gh.shape[1]))
    flat = w.reshape(cout, -1)
    return mixed_weight_pallas(flat, gh).reshape(w.shape)


def mixed_conv2d(x: jax.Array, w: jax.Array, alpha: jax.Array,
                 delta_hat: jax.Array, gamma_hat: jax.Array,
                 stride: int, groups: int = 1) -> jax.Array:
    """Eq. (6): Conv(X_hat, stack(W_hat_i)), NHWC x (Cout, Kx, Ky, Cin/g).

    SAME padding everywhere (all four benchmark models use it).
    """
    xq = effective_act(x, alpha, delta_hat)
    wq = effective_weight(w, gamma_hat)
    # lax conv wants OIHW-style filter (Cout, Cin/g, Kx, Ky) given NHWC io.
    return jax.lax.conv_general_dilated(
        xq, jnp.transpose(wq, (1, 2, 3, 0)),
        window_strides=(stride, stride), padding="SAME",
        feature_group_count=groups,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def mixed_dense(x: jax.Array, w: jax.Array, b: jax.Array | None,
                alpha: jax.Array, delta_hat: jax.Array,
                gamma_hat: jax.Array) -> jax.Array:
    """FC layer: per-output-neuron weight precision (w is (Cout, Cin))."""
    xq = effective_act(x, alpha, delta_hat)
    wq = effective_weight(w, gamma_hat)
    y = xq @ wq.T
    if b is not None:
        y = y + b
    return y
